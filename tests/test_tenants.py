"""Fused multi-tenant execution (ISSUE 4): batched == unbatched parity,
bucket-roster churn without recompiles, and the service front-end's
coalescing / error / edge paths.

The two load-bearing claims:
  * a FusedEngine's (density, mask, passes) triple is bit-identical to an
    unbatched DeltaEngine fed the same stream — for single queries, group
    flushes, epoch refreshes, the dense (GEMV) bucket representation and
    the sparse (scatter) one;
  * joining / evicting a tenant in a warm bucket is a lane row swap: the
    compile counter must not move.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.pbahmani import pbahmani_np
from repro.stream import (
    DeltaEngine, FusedEngine, FusedPool, GraphRegistry, StreamService,
    ingest_group, query_group,
)
from repro.stream.fused import DENSE_NODE_CAP, MIN_LANES


def _churn(rng, n, edges):
    ins = rng.integers(0, n, (int(rng.integers(1, 50)), 2))
    dels = None
    if edges and rng.random() < 0.6:
        pool = np.asarray(sorted(edges))
        dels = pool[rng.random(len(pool)) < 0.3]
        for u, v in dels:
            edges.discard((int(u), int(v)))
    for u, v in ins:
        u, v = int(u), int(v)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return ins, dels


# ---------------------------------------------------------------------------
# bit-identity: fused == unbatched
# ---------------------------------------------------------------------------
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_fused_matches_unbatched_stream(seed):
    """After any insert/delete sequence — including epoch refreshes — the
    fused engine's triple equals the unbatched engine's, both pruned and
    unpruned."""
    rng = np.random.default_rng(seed)
    n = 150
    pool = FusedPool()
    for pruned in (False, True):
        ref = DeltaEngine(n_nodes=n, refresh_every=4, pruned=pruned)
        fe = FusedEngine(f"t{pruned}", pool, n, refresh_every=4,
                         pruned=pruned)
        edges: set = set()
        for step in range(8):
            ins, dels = _churn(rng, n, edges)
            ref.apply_updates(insert=ins, delete=dels)
            fe.apply_updates(insert=ins, delete=dels)
            q1, q2 = ref.query(), fe.query()
            assert q1.density == q2.density, (pruned, step)
            assert np.array_equal(q1.mask, q2.mask), (pruned, step)
            assert q1.passes == q2.passes, (pruned, step)
            assert q1.warm_density == q2.warm_density, (pruned, step)
            assert q1.refreshed == q2.refreshed, (pruned, step)


def test_fused_group_query_parity_and_lane_growth():
    """A group flush answers every tenant bit-identically to its own
    unbatched twin; growing past MIN_LANES preserves resident lanes."""
    rng = np.random.default_rng(1)
    n = 120
    pool = FusedPool()
    refs, fused = [], {}
    for i in range(MIN_LANES + 2):  # forces one lane-stack growth
        r = DeltaEngine(n_nodes=n, refresh_every=10**9)
        f = FusedEngine(f"t{i}", pool, n, refresh_every=10**9)
        ins = rng.integers(0, n, (60 + 10 * i, 2))
        r.apply_updates(insert=ins)
        f.apply_updates(insert=ins)
        refs.append(r)
        fused[f"t{i}"] = f
    assert next(iter(fused.values())).batch.lanes > MIN_LANES
    results = query_group(fused)
    for i, r in enumerate(refs):
        q1, q2 = r.query(), results[f"t{i}"]
        assert q1.density == q2.density and q1.passes == q2.passes
        assert np.array_equal(q1.mask, q2.mask)
    # memoization: a second group flush returns the cached objects
    again = query_group(fused)
    assert all(again[k] is results[k] for k in fused)


def test_fused_sparse_bucket_parity():
    """Vertex spaces above DENSE_NODE_CAP use the scatter-based vmapped
    peel — same bit-identity contract."""
    rng = np.random.default_rng(2)
    n = DENSE_NODE_CAP + 10  # node capacity 1024 > DENSE_NODE_CAP
    pool = FusedPool()
    ref = DeltaEngine(n_nodes=n, refresh_every=10**9, pruned=False)
    fe = FusedEngine("big", pool, n, refresh_every=10**9, pruned=False)
    ins = rng.integers(0, n, (800, 2))
    ref.apply_updates(insert=ins)
    fe.apply_updates(insert=ins)
    assert not fe.batch.dense
    q1, q2 = ref.query(), fe.query()
    assert q1.density == q2.density and q1.passes == q2.passes
    assert np.array_equal(q1.mask, q2.mask)


def test_fused_sharded_bucket_parity_one_device():
    """ISSUE 9: fused+sharded tenants share a mesh-sharded bucket stack
    whose batched programs run vmap-inside-shard_map — on the in-process
    1-device mesh every tenant stays bit-identical to its solo twin, and
    cbds routes through the same sharded tier (the multi-device version of
    this oracle lives in tests/test_shard.py subprocesses)."""
    rng = np.random.default_rng(9)
    n = 150
    reg = GraphRegistry(fused=True, sharded=True)
    names = ["a", "b", "c"]
    solo, edge_sets = {}, {}
    for t in names:
        eng = reg.register(t, n_nodes=n)
        assert eng.sharded and eng.kind == "fused+sharded"
        solo[t] = DeltaEngine(n_nodes=n, refresh_every=32)
        edge_sets[t] = set()
    for step in range(6):
        ups = {}
        for t in names:
            ins, dels = _churn(rng, n, edge_sets[t])
            ups[t] = (ins, dels)
            solo[t].apply_updates(insert=ins, delete=dels)
        ingest_group(ups, reg.engines())
        res = query_group(reg.engines())
        for t in names:
            qs = solo[t].query()
            assert res[t].density == qs.density, (step, t)
            assert res[t].passes == qs.passes, (step, t)
            assert np.array_equal(np.asarray(res[t].mask), qs.mask), (step, t)
    for t in names:
        cf, cs = reg.get(t).cbds(), solo[t].cbds()
        assert cf["density"] == cs["density"] and cf["n_legit"] == cs["n_legit"]


def test_fused_capacity_migration_rebuckets():
    """A buffer regrow moves the tenant to the matching capacity bucket
    (evict + join) with exact results on the other side."""
    rng = np.random.default_rng(3)
    n = 100
    pool = FusedPool()
    fe = FusedEngine("grow", pool, n, capacity=256, refresh_every=10**9)
    fe.apply_updates(insert=rng.integers(0, n, (60, 2)))
    fe.query()
    first = fe.batch
    # overflow the 256-slot buffer: capacity doubles, bucket changes
    big = rng.integers(0, n, (2000, 2))
    fe.apply_updates(insert=big)
    assert fe.buffer.capacity > 256
    assert fe.batch is not first
    assert "grow" not in first.lane_of
    rho, mask, passes = pbahmani_np(fe.buffer.to_graph())
    q = fe.query()
    assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
    assert np.array_equal(q.mask, mask[:n]) and q.passes == passes


def test_fused_join_evict_zero_recompiles():
    """Tenant churn in a warm bucket is a row swap: evict one tenant, join
    another, ingest and run single + group queries — the compile counter
    must not move. (pruned=False: plan-bucket shapes are data-dependent
    and compile on regrow even in the unbatched engine.)"""
    rng = np.random.default_rng(4)
    n = 100
    pool = FusedPool()
    fused = {}
    for i in range(4):
        f = FusedEngine(f"t{i}", pool, n, refresh_every=10**9, pruned=False)
        f.apply_updates(insert=rng.integers(0, n, (48, 2)))
        f.query()
        fused[f"t{i}"] = f
    for f in fused.values():
        f._cached_query = None  # defeat memoization: warm the group shapes
    query_group(fused)
    ingest_group({k: (rng.integers(0, n, (20, 2)), None) for k in fused},
                 fused)
    before = DeltaEngine.compile_count()

    fused.pop("t1").release()
    nf = FusedEngine("t9", pool, n, refresh_every=10**9, pruned=False)
    nf.apply_updates(insert=rng.integers(0, n, (48, 2)))
    fused["t9"] = nf
    nf.query()
    for f in fused.values():
        f._cached_query = None
    query_group(fused)
    ingest_group({k: (rng.integers(0, n, (20, 2)), None) for k in fused},
                 fused)
    assert DeltaEngine.compile_count() == before, "join/evict recompiled"


def test_fused_ingest_group_parity():
    """One fused [T, B] scatter applies many tenants' batches with the
    same outcome as per-tenant dispatch."""
    rng = np.random.default_rng(5)
    n = 90
    pool = FusedPool()
    refs, fused, upd = [], {}, {}
    for i in range(3):
        r = DeltaEngine(n_nodes=n, refresh_every=10**9)
        f = FusedEngine(f"t{i}", pool, n, refresh_every=10**9)
        seedb = rng.integers(0, n, (40, 2))
        r.apply_updates(insert=seedb)
        f.apply_updates(insert=seedb)
        ins = rng.integers(0, n, (25, 2))
        dels = np.asarray(sorted(r.buffer._slot))[:5]
        upd[f"t{i}"] = (ins, dels)
        refs.append(r)
        fused[f"t{i}"] = f
    stats = ingest_group(upd, fused)
    for i, r in enumerate(refs):
        s_ref = r.apply_updates(insert=upd[f"t{i}"][0],
                                delete=upd[f"t{i}"][1])
        assert stats[f"t{i}"].n_inserted == s_ref.n_inserted
        assert stats[f"t{i}"].n_deleted == s_ref.n_deleted
    results = query_group(fused)
    for i, r in enumerate(refs):
        assert results[f"t{i}"].density == r.query().density


def test_dense_ingest_is_one_dispatch(monkeypatch):
    """ISSUE 5 satellite: the dense-bucket ingest fuses the COO scatter and
    the adjacency scatter into ONE program — counted two ways: the batch's
    dispatch counter tracks its ingest counter 1:1, and monkeypatched jit
    entry points see exactly one launch per ingest."""
    from repro.stream import fused as fused_mod

    calls = []
    real_dense = fused_mod._batched_apply_dense_jit
    real_sparse = fused_mod._batched_apply_jit
    monkeypatch.setattr(
        fused_mod, "_batched_apply_dense_jit",
        lambda *a, **k: (calls.append("dense"), real_dense(*a, **k))[1])
    monkeypatch.setattr(
        fused_mod, "_batched_apply_jit",
        lambda *a, **k: (calls.append("sparse"), real_sparse(*a, **k))[1])

    rng = np.random.default_rng(9)
    n = 80
    pool = FusedPool()
    ref = DeltaEngine(n_nodes=n, refresh_every=10**9)
    eng = FusedEngine("t0", pool, n, refresh_every=10**9)
    seedb = rng.integers(0, n, (60, 2))
    ref.apply_updates(insert=seedb)
    eng.apply_updates(insert=seedb)
    assert eng.batch.dense  # 80 nodes -> dense (GEMV) bucket
    d0 = eng.batch.n_ingest_dispatches
    calls.clear()
    for _ in range(3):
        ins = rng.integers(0, n, (16, 2))
        ref.apply_updates(insert=ins)
        eng.apply_updates(insert=ins)
    assert calls == ["dense"] * 3  # one program per ingest, no second scatter
    assert eng.batch.n_ingest_dispatches == d0 + 3
    assert eng.batch.n_ingests == eng.batch.n_ingest_dispatches
    # and the fused program's state matches the unbatched engine exactly
    q_ref, q = ref.query(), eng.query()
    assert q.density == q_ref.density
    assert np.array_equal(q.mask, q_ref.mask)
    assert q.passes == q_ref.passes


def test_ingest_group_partial_failure_stays_consistent():
    """A failing tenant mid-ingest must not leave earlier tenants' device
    lanes stale: their host buffers already committed, so the staged rows
    must still dispatch (the code-review repro: density read 3.33 instead
    of 2.0 until an unrelated resync)."""
    svc = StreamService(fused=True)
    svc.create_tenant("good", n_nodes=20)
    svc.create_tenant("bad", n_nodes=10)
    svc.apply_updates("good", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    svc.density("good")
    r = svc.ingest_many({
        "good": (np.array([[2, 3], [3, 4]]), None),
        "bad": (np.array([[0, 99]]), None),   # endpoint out of range
    })
    assert not r.ok and "out of range" in r.error
    # good's host buffer committed (5 edges) AND its lane received the row
    d = svc.density("good")
    rho, mask, passes = pbahmani_np(
        svc.registry.get("good").buffer.to_graph())
    assert d.ok and d.value["density"] == pytest.approx(rho)
    m = svc.membership("good")
    assert np.array_equal(m.value["mask"], mask[:20])


def test_flush_survives_engine_failure():
    """A tenant whose query raises at flush time must not orphan the other
    pending tickets — every ticket gets a response."""
    svc = StreamService(fused=True, coalesce_window_ms=1e9)
    svc.create_tenant("ok", n_nodes=20)
    svc.apply_updates("ok", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    svc.create_tenant("boom", n_nodes=20)
    eng = svc.registry.get("boom")
    def explode():
        raise ValueError("engine exploded")
    # raises inside query_group (generation -1 forces a resync there) AND
    # inside the per-tenant fallback query
    eng._resync_device = explode
    t_ok = svc.submit_density("ok")
    t_boom = svc.submit_density("boom")
    assert svc.flush() == 2
    r_ok, r_boom = svc.poll(t_ok), svc.poll(t_boom)
    assert r_ok is not None and r_ok.ok
    assert r_ok.value["density"] == pytest.approx(1.0)
    assert r_boom is not None and not r_boom.ok
    assert "exploded" in r_boom.error


def test_group_helpers_accept_unbatched_engines():
    """query_group / ingest_group route plain DeltaEngines through their
    own paths, so mixed fused/unfused registries work (top_k, flush)."""
    plain = DeltaEngine(n_nodes=30, refresh_every=10**9)
    plain.apply_updates(insert=np.array([[0, 1], [1, 2], [0, 2]]))
    pool = FusedPool()
    fe = FusedEngine("f", pool, 30, refresh_every=10**9)
    fe.apply_updates(insert=np.array([[4, 5]]))
    res = query_group({"plain": plain, "f": fe})
    assert res["plain"].density == pytest.approx(1.0)
    assert res["f"].density == pytest.approx(0.5)
    stats = ingest_group({"plain": (np.array([[2, 3]]), None),
                          "f": (np.array([[5, 6]]), None)},
                         {"plain": plain, "f": fe})
    assert stats["plain"].n_inserted == 1 and stats["f"].n_inserted == 1


# ---------------------------------------------------------------------------
# registry roster
# ---------------------------------------------------------------------------
def test_registry_fused_roster_and_conflicts():
    reg = GraphRegistry(fused=True, max_tenants=2)
    a = reg.register("a", n_nodes=100)
    assert isinstance(a, FusedEngine)
    a.apply_updates(insert=np.array([[0, 1], [1, 2]]))
    a.query()
    st_ = reg.stats("a")
    assert st_.fused and st_.lane >= 0 and st_.batch_lanes >= MIN_LANES
    # conflicting fused flag on re-register raises
    with pytest.raises(ValueError, match="fused"):
        reg.register("a", n_nodes=100, fused=False)
    # fused + sharded composes (ISSUE 9): accepted, placed in a sharded
    # bucket stack, with the placement surfaced in the stats
    b = reg.register("b", n_nodes=100, sharded=True)
    assert isinstance(b, FusedEngine) and b.sharded
    assert b.kind == "fused+sharded"
    b.apply_updates(insert=np.array([[0, 1], [1, 2]]))
    b.query()
    st_b = reg.stats("b")
    assert st_b.fused and st_b.sharded and st_b.placement == "fused+sharded"
    assert st_b.lane >= 0
    reg.remove("b")
    # LRU eviction releases the lane back to the bucket
    batch = a.batch
    reg.register("c", n_nodes=100)
    reg.get("c")
    reg.register("d", n_nodes=100)  # evicts "a" (LRU)
    assert "a" not in reg and "a" not in batch.lane_of
    # remove() releases too
    d = reg.get("d")
    reg.remove("d")
    assert d.batch is None


# ---------------------------------------------------------------------------
# service: error/edge paths + coalescing
# ---------------------------------------------------------------------------
def test_service_unknown_tenant_paths():
    svc = StreamService(fused=True)
    for op in (lambda: svc.density("ghost"),
               lambda: svc.membership("ghost"),
               lambda: svc.apply_updates("ghost", insert=np.array([[0, 1]])),
               lambda: svc.stats("ghost"),
               lambda: svc.ingest_many({"ghost": (np.array([[0, 1]]), None)})):
        r = op()
        assert not r.ok and "ghost" in r.error
    assert svc.metrics.n_errors == 5


def test_service_empty_graph_density():
    svc = StreamService(fused=True)
    assert svc.create_tenant("empty", n_nodes=32).ok
    d = svc.density("empty")
    assert d.ok and d.value["density"] == 0.0
    m = svc.membership("empty")
    assert m.ok and m.value["n_members"] == 0


def test_service_top_k_exceeding_tenant_count():
    svc = StreamService(fused=True)
    svc.create_tenant("x", n_nodes=50)
    svc.create_tenant("y", n_nodes=50)
    svc.apply_updates("x", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    svc.apply_updates("y", insert=np.array([[3, 4]]))
    top = svc.top_k_densest(k=99)
    assert top.ok and len(top.value) == 2  # all tenants, densest first
    assert top.value[0]["tenant"] == "x"


def test_service_coalescing_window_and_flush():
    svc = StreamService(fused=True, coalesce_window_ms=1e9)
    svc.create_tenant("a", n_nodes=40)
    svc.create_tenant("b", n_nodes=40)
    svc.apply_updates("a", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    svc.apply_updates("b", insert=np.array([[4, 5]]))
    ta = svc.submit_density("a")
    tb = svc.submit_density("b")
    tg = svc.submit_density("ghost")  # unknown tenant: error at flush
    assert svc.poll(ta) is None      # window still open: pending
    assert svc.flush() == 3
    ra, rb, rg = svc.poll(ta), svc.poll(tb), svc.poll(tg)
    assert ra.ok and ra.value["density"] == pytest.approx(1.0)
    assert rb.ok and rb.value["density"] == pytest.approx(0.5)
    assert not rg.ok and "ghost" in rg.error
    assert svc.poll(ta) is None      # results pop once
    # window <= 0 degenerates to flush-per-submit
    svc0 = StreamService(fused=True)
    svc0.create_tenant("a", n_nodes=40)
    svc0.apply_updates("a", insert=np.array([[0, 1]]))
    t0 = svc0.submit_density("a")
    assert svc0.poll(t0).ok


def test_service_coalescing_flush_on_shutdown():
    svc = StreamService(fused=True, coalesce_window_ms=1e9)
    svc.create_tenant("a", n_nodes=40)
    svc.apply_updates("a", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    t = svc.submit_density("a")
    assert svc.poll(t) is None
    assert svc.shutdown() == 1       # pending queries answered at shutdown
    r = svc.poll(t)
    assert r is not None and r.ok and r.value["density"] == pytest.approx(1.0)
    assert svc.shutdown() == 0       # idempotent
    with pytest.raises(RuntimeError):
        svc.submit_density("a")      # no new submissions after shutdown
