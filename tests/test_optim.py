"""Optimizers, schedules, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor, adamw, clip_by_global_norm, constant, dequantize_int8,
    global_norm, linear_warmup_cosine, quantize_int8, sgdm,
)


def test_adamw_matches_manual_scalar():
    """One AdamW step on a scalar vs hand-computed values."""
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.5])}
    opt = adamw(lr=0.1, b1=0.9, b2=0.99, eps=0.0, weight_decay=0.0,
                grad_clip=None)
    st = opt.init(p)
    p2, st2 = opt.update(g, st, p)
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.99)
    expected = 2.0 - 0.1 * mhat / np.sqrt(nhat)
    np.testing.assert_allclose(float(p2["w"][0]), expected, rtol=1e-6)


def test_grad_clip_effective():
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}   # norm 200 >> 1
    opt = sgdm(lr=1.0, momentum=0.0, grad_clip=1.0)
    p2, _ = opt.update(g, opt.init(p), p)
    # clipped grad has norm 1 -> per-element 0.5
    np.testing.assert_allclose(np.asarray(p["w"] - p2["w"]), 0.5 * np.ones(4),
                               rtol=1e-5)


def test_adafactor_factored_state_memory():
    p = {"big": jnp.ones((512, 1024)), "small": jnp.ones((4, 8))}
    st = adafactor(1e-3).init(p)
    assert set(st["v"]["big"]) == {"vr", "vc"}
    assert st["v"]["big"]["vr"].shape == (512,)
    assert st["v"]["big"]["vc"].shape == (1024,)
    assert set(st["v"]["small"]) == {"v"}     # too small to factor
    # factored state is ~1000x smaller than a full second moment
    full = p["big"].size
    fact = st["v"]["big"]["vr"].size + st["v"]["big"]["vc"].size
    assert fact < full / 300


def test_adafactor_converges_quadratic():
    p = {"w": jnp.asarray(5.0)}
    opt = adafactor(0.5, grad_clip=None)
    st = opt.init(p)
    for _ in range(100):
        g = {"w": 2 * p["w"]}
        p, st = opt.update(g, st, p)
    assert abs(float(p["w"])) < 0.3


def test_schedules():
    f = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100,
                             final_frac=0.1)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-5)
    assert float(f(5)) == pytest.approx(0.5, abs=1e-5)
    assert float(constant(0.3)(77)) == pytest.approx(0.3)


def test_global_norm_and_clip():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(gn) == pytest.approx(5.0)


def test_int8_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 10
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    per_row_max = np.abs(np.asarray(x)).max(axis=1)
    assert (err.max(axis=1) <= per_row_max / 127 + 1e-6).all()


def test_bf16_param_training_stays_bf16():
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    opt = adamw(1e-2)
    p2, _ = opt.update(g, opt.init(p), p)
    assert p2["w"].dtype == jnp.bfloat16
