"""Hypothesis compat shim: property tests degrade to deterministic examples.

The test suite uses hypothesis for randomized property tests, but tier-1 must
pass on a bare interpreter (the container has no hypothesis wheel). Importing
``given``/``settings``/``strategies`` from here uses the real library when it
is installed (``pip install -r requirements-dev.txt``) and otherwise falls
back to a deterministic re-implementation: each ``@given`` test runs
``max_examples`` examples drawn from a PRNG seeded by the test name, so the
fallback is reproducible across runs and machines.

Only the strategy surface the suite uses is implemented: ``st.integers`` and
``st.sampled_from``. Extend here before using new strategies in tests.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        """Deterministic stand-ins for ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(items):
            seq = list(items)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def floats(min_value, max_value, **_kw):
            span = float(max_value) - float(min_value)
            return _Strategy(
                lambda rng: float(min_value) + span * float(rng.random()))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                k = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(k)]

            return _Strategy(draw)

    st = _St()

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                # seed from the test name: stable across runs and file moves
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    fn(*(s.example(rng) for s in strategies),
                       **{k: s.example(rng)
                          for k, s in kw_strategies.items()})

            # keep the test name but NOT __wrapped__: pytest must see a
            # zero-argument signature, not the strategy parameters
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        del deadline

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
