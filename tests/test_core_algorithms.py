"""P-Bahmani, k-core, CBDS-P, Charikar: correctness + the paper's claims.

Falsifiable claims validated (paper §3, §4):
  * P-Bahmani: rho~ >= rho* / (2+2eps)   [Bahmani et al. thm]
  * passes = O(log_{1+eps} n)
  * densest-core density is a 2-approximation (Tatti 2019)
  * CBDS-P >= densest-core density (>= phase-1), i.e. beats the plain
    2-approximation whenever any legit vertex exists (paper Table 3)
  * coreness values match networkx.core_number
"""
import math

import networkx as nx
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    cbds_np, cbds_p, charikar, exact_densest, kcore_decompose, kcore_np,
    pbahmani, pbahmani_np,
)
from repro.graphs.generators import erdos_renyi
from repro.graphs.graph import Graph


def random_graph(seed: int, n: int, p: float) -> Graph:
    return erdos_renyi(n, p, seed=seed)


# ---------------------------------------------------------------------------
# jax == numpy oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("eps", [0.0, 0.05, 0.5])
def test_pbahmani_jax_matches_np(er_graph, eps):
    rho_j, mask_j, passes_j = pbahmani(er_graph, eps=eps)
    rho_n, mask_n, passes_n = pbahmani_np(er_graph, eps=eps)
    assert passes_j == passes_n
    assert rho_j == pytest.approx(rho_n, rel=1e-6)
    assert np.array_equal(mask_j, mask_n)


def test_kcore_jax_matches_np(er_graph):
    cj, dj, kj, vj, ej = kcore_decompose(er_graph)
    cn, dn, kn, vn, en = kcore_np(er_graph)
    assert np.array_equal(cj, cn)
    assert (dj, kj, vj, ej) == (pytest.approx(dn), kn, vn, en)


def test_cbds_jax_matches_np(er_graph):
    rj = cbds_p(er_graph)
    rn = cbds_np(er_graph)
    assert rj["density"] == pytest.approx(rn["density"], rel=1e-5)
    assert rj["k_star"] == rn["k_star"]
    assert np.array_equal(rj["member_mask"], rn["member_mask"])


# ---------------------------------------------------------------------------
# coreness vs networkx
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_coreness_matches_networkx(seed):
    g = random_graph(seed, 120, 0.06)
    coreness, *_ = kcore_decompose(g)
    core_nx = nx.core_number(g.to_networkx())
    for v, c in core_nx.items():
        assert coreness[v] == c, f"vertex {v}: {coreness[v]} != {c}"


# ---------------------------------------------------------------------------
# the paper's approximation claims
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.0, 0.05, 0.5]))
def test_pbahmani_approximation_bound(seed, eps):
    g = random_graph(seed, 90, 0.08)
    if g.n_edges == 0:
        return
    rho_star, _ = exact_densest(g)
    rho, _, passes = pbahmani(g, eps=eps)
    assert rho >= rho_star / (2 + 2 * eps) - 1e-5
    # O(log_{1+eps} n) passes (loose constant)
    if eps > 0:
        bound = 4 + 4 * math.log(max(g.n_nodes, 2)) / math.log(1 + eps)
        assert passes <= bound


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_cbds_beats_2approx_bound(seed):
    g = random_graph(seed, 90, 0.08)
    if g.n_edges == 0:
        return
    rho_star, _ = exact_densest(g)
    res = cbds_p(g)
    # phase-1 densest core is a 2-approx; CBDS-P only improves on it
    assert res["core_density"] >= rho_star / 2 - 1e-5
    assert res["density"] >= res["core_density"] - 1e-5
    assert res["density"] <= rho_star + 1e-4  # a valid subgraph density
    # reported density matches the density of the returned member set
    assert g.subgraph_density(res["member_mask"]) == pytest.approx(
        res["density"], abs=2e-4)


def test_charikar_2approx(er_graph):
    rho_star, _ = exact_densest(er_graph)
    rho, mask = charikar(er_graph)
    assert rho >= rho_star / 2 - 1e-6
    assert er_graph.subgraph_density(mask) == pytest.approx(rho, abs=1e-9)


def test_pbahmani_eps0_matches_charikar_class(er_graph):
    """eps=0 P-Bahmani is in the same accuracy class as Charikar (2-approx);
    on most graphs the densities agree (paper Table 3 observation)."""
    rho_star, _ = exact_densest(er_graph)
    rho_pb, _, _ = pbahmani(er_graph, eps=0.0)
    rho_ch, _ = charikar(er_graph)
    assert rho_pb >= rho_star / 2 - 1e-6
    assert rho_ch >= rho_star / 2 - 1e-6


def test_planted_recovery(planted):
    g, mask_true, rho_planted = planted
    res = cbds_p(g)
    rho_pb, mask_pb, _ = pbahmani(g, eps=0.05)
    # both methods find (at least) the planted block's density
    assert res["density"] >= rho_planted * 0.98
    assert rho_pb >= rho_planted / (2 + 2 * 0.05) - 1e-5
    # CBDS member set overlaps the planted block heavily
    inter = (res["member_mask"] & mask_true).sum()
    assert inter >= 0.9 * mask_true.sum()


def test_cbds_multi_round_monotone(er_graph):
    d1 = cbds_p(er_graph, rounds=1)["density"]
    d3 = cbds_p(er_graph, rounds=3)["density"]
    assert d3 >= d1 - 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_cbds_rounds3_jax_matches_np(seed):
    """Regression: multi-round phase 2 must absorb the same legit sets in
    both paths. The legitimacy threshold is integer-exact (e_into > m_e//m_v)
    so jax (formerly float32 rho) and numpy (float64 rho) cannot diverge on
    boundary vertices as rounds compound."""
    g = random_graph(seed, 150, 0.06)
    rn = cbds_np(g, rounds=3)
    rj = cbds_p(g, rounds=3)
    assert rj["density"] == pytest.approx(rn["density"], rel=1e-6)
    assert rj["n_legit"] == rn["n_legit"]
    assert np.array_equal(rj["member_mask"], rn["member_mask"])
    # bookkept edge count == actual induced edge count (no double-counting
    # as later rounds absorb sets overlapping earlier rounds' neighborhoods)
    assert g.subgraph_density(rj["member_mask"]) == pytest.approx(
        rj["density"], abs=2e-4)


def test_paper_table3_shape(named_graph):
    """Exact == P-Bahmani(0) == CBDS-P on the small named graphs
    (the pattern of paper Table 3's first rows)."""
    rho_star, _ = exact_densest(named_graph)
    rho_pb, _, _ = pbahmani(named_graph, eps=0.0)
    res = cbds_p(named_graph)
    assert rho_pb == pytest.approx(rho_star, abs=1e-5)
    assert res["density"] == pytest.approx(rho_star, abs=1e-5)
