"""Streaming subsystem: buffer invariants, the incremental-vs-recompute
oracle, compile-count stability, registry LRU, service front-end, stream IO.

The two load-bearing claims (ISSUE 1 acceptance criteria):
  * after ANY sequence of insert/delete batches, the incremental engine's
    density equals a from-scratch ``pbahmani_np`` recompute on the
    materialized graph (exact trajectory, not an approximation);
  * repeated same-capacity update batches trigger ZERO recompilations after
    warmup (the shape-bucketing contract).
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.cbds import cbds_np
from repro.core.pbahmani import pbahmani_np
from repro.graphs.graph import Graph
from repro.graphs.io import load_edge_stream, save_edge_stream
from repro.stream import DeltaEngine, EdgeBuffer, GraphRegistry, StreamService
from repro.stream.buffer import next_pow2


def materialize(edges: set, n_nodes: int) -> Graph:
    pairs = (np.asarray(sorted(edges), dtype=np.int64) if edges
             else np.zeros((0, 2), np.int64))
    return Graph.from_edges(pairs, n_nodes=n_nodes)


def random_stream(rng, n_nodes, n_batches, max_batch):
    """Yield (insert, delete, mirror) where mirror is the running edge set."""
    edges: set = set()
    for _ in range(n_batches):
        ins = rng.integers(0, n_nodes, (int(rng.integers(1, max_batch)), 2))
        if edges and rng.random() < 0.7:
            pool = np.asarray(sorted(edges))
            take = rng.random(len(pool)) < 0.3
            dels = pool[take]
        else:
            dels = None
        if dels is not None:
            for u, v in dels:
                edges.discard((int(u), int(v)))
        for u, v in ins:
            u, v = int(u), int(v)
            if u != v:
                edges.add((min(u, v), max(u, v)))
        yield ins, dels, edges


# ---------------------------------------------------------------------------
# EdgeBuffer
# ---------------------------------------------------------------------------
def test_buffer_insert_delete_dedup():
    buf = EdgeBuffer(n_nodes=10)
    ins, ins_slots, dele, del_slots = buf.apply(
        insert=np.array([[0, 1], [1, 0], [2, 3], [4, 4]]))
    assert buf.n_edges == 2                   # dup orientation + self-loop
    assert ins.shape == (2, 2) and ins_slots.shape == (2,)
    assert (0, 1) in buf and (1, 0) in buf and (4, 4) not in buf
    ins2, _, dele2, _ = buf.apply(insert=np.array([[0, 1]]),
                                  delete=np.array([[3, 2], [5, 6]]))
    assert ins2.shape[0] == 0                 # already present
    assert dele2.shape[0] == 1                # (5,6) absent, dropped
    assert buf.n_edges == 1


def test_buffer_device_view_sentinel_and_symmetry():
    buf = EdgeBuffer(n_nodes=10)
    buf.apply(insert=np.array([[0, 1], [2, 3]]))
    src, dst = buf.device_view()
    assert src.shape == (2 * buf.capacity,)
    valid = src < buf.sentinel
    assert valid.sum() == 2 * buf.n_edges     # symmetric pairs
    assert (dst[~valid] == buf.sentinel).all()
    pairs = set(zip(src[valid].tolist(), dst[valid].tolist()))
    assert (0, 1) in pairs and (1, 0) in pairs


def test_buffer_pow2_growth_and_generation():
    buf = EdgeBuffer(n_nodes=100, capacity=256)
    gen0 = buf.generation
    rng = np.random.default_rng(0)
    # overfill: 100-node simple graph holds at most 4950 edges
    buf.apply(insert=rng.integers(0, 100, (4000, 2)))
    assert buf.capacity == next_pow2(buf.capacity)  # stayed a power of two
    assert buf.capacity >= buf.n_edges
    assert buf.generation > gen0
    g = buf.to_graph()
    assert g.n_edges == buf.n_edges


def test_buffer_compact_preserves_graph():
    buf = EdgeBuffer(n_nodes=50)
    rng = np.random.default_rng(1)
    buf.apply(insert=rng.integers(0, 50, (200, 2)))
    pool = np.asarray(sorted(buf._slot))[::3]
    buf.apply(delete=pool)
    before = sorted(buf._slot)
    buf.epoch_compact()
    assert sorted(buf._slot) == before
    src, _ = buf.device_view()
    # compaction is hole-free: the valid prefix is dense
    assert (src[: buf.n_edges] < buf.sentinel).all()
    assert (src[buf.n_edges : buf.capacity] == buf.sentinel).all()


def test_buffer_rejects_out_of_range():
    buf = EdgeBuffer(n_nodes=10)
    with pytest.raises(ValueError):
        buf.apply(insert=np.array([[0, 10]]))


def test_buffer_epoch_shrink_with_hysteresis():
    """ISSUE 3 bugfix: capacity used to only ever grow. An epoch compact
    with shrink=True halves down to pow-2 with 2x headroom — but only below
    SHRINK_FRACTION occupancy, so stable graphs never thrash."""
    from repro.stream.buffer import MIN_CAPACITY, SHRINK_FRACTION

    buf = EdgeBuffer(n_nodes=100, capacity=1024, compact_threshold=None)
    rng = np.random.default_rng(2)
    buf.apply(insert=rng.integers(0, 100, (400, 2)))
    n_mid = buf.n_edges
    assert buf.capacity == 1024
    # above the hysteresis floor: no shrink
    assert n_mid > 1024 * SHRINK_FRACTION
    assert buf.shrink_target() is None
    assert not buf.epoch_compact(shrink=True)
    assert buf.capacity == 1024

    # contract far below the floor: shrink to next_pow2(2*live)
    pool = np.asarray(sorted(buf._slot))
    buf.apply(delete=pool[60:])
    assert buf.n_edges == 60
    before = buf.to_graph()
    gen0 = buf.generation
    assert buf.epoch_compact(shrink=True)
    assert buf.capacity == max(next_pow2(120), MIN_CAPACITY) == 256
    assert buf.generation > gen0
    after = buf.to_graph()
    assert before.n_edges == after.n_edges
    assert np.array_equal(before.src, after.src)
    # post-shrink occupancy <= 50%: the next regrow needs the graph to double
    assert buf.n_edges <= buf.capacity // 2
    # and the buffer still works: inserts land in the shrunken slot space
    buf.apply(insert=np.array([[0, 99]]))
    assert (0, 99) in buf


def test_buffer_tombstone_autocompact():
    """ISSUE 3 bugfix: delete-heavy streams fragment the slot space with no
    compaction threshold. When un-recycled holes exceed compact_threshold
    the buffer compacts mid-stream and bumps generation (so engines resync
    and executables re-bucket)."""
    buf = EdgeBuffer(n_nodes=100, capacity=256, compact_threshold=0.3)
    rng = np.random.default_rng(3)
    buf.apply(insert=rng.integers(0, 100, (250, 2)))
    n0 = buf.n_edges
    gen0 = buf.generation
    pool = np.asarray(sorted(buf._slot))
    buf.apply(delete=pool[: n0 - 50])  # way past 0.3 * 256 holes
    assert buf.generation > gen0                 # compaction happened
    assert buf.tombstone_fraction == 0.0         # holes cleared
    src, _ = buf.device_view()
    assert (src[: buf.n_edges] < buf.sentinel).all()   # dense prefix
    assert (src[buf.n_edges: buf.capacity] == buf.sentinel).all()

    # holes below the threshold leave the layout alone (O(batch) contract)
    buf2 = EdgeBuffer(n_nodes=100, capacity=256, compact_threshold=0.5)
    buf2.apply(insert=rng.integers(0, 100, (100, 2)))
    gen1 = buf2.generation
    pool2 = np.asarray(sorted(buf2._slot))
    buf2.apply(delete=pool2[:20])
    assert buf2.generation == gen1
    assert buf2.tombstone_fraction > 0.0

    # threshold=None disables mid-stream compaction entirely
    buf3 = EdgeBuffer(n_nodes=100, capacity=256, compact_threshold=None)
    buf3.apply(insert=rng.integers(0, 100, (250, 2)))
    gen3 = buf3.generation
    buf3.apply(delete=np.asarray(sorted(buf3._slot)))
    assert buf3.generation == gen3


def test_buffer_hole_reuse_keeps_fragmentation_low():
    """Freed slots recycle before fresh ones, so churn (delete+insert in
    one batch) leaves no tombstones behind."""
    buf = EdgeBuffer(n_nodes=100, capacity=256)
    buf.apply(insert=np.array([[0, 1], [1, 2], [2, 3]]))
    buf.apply(delete=np.array([[0, 1]]), insert=np.array([[4, 5]]))
    assert buf.tombstone_fraction == 0.0
    assert buf.n_edges == 3


# ---------------------------------------------------------------------------
# DeltaEngine: the incremental == from-scratch oracle
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_engine_matches_cold_recompute(seed):
    """Acceptance criterion: after any randomized insert/delete sequence the
    engine's density/mask/passes equal pbahmani_np on the materialized
    graph. refresh_every=4 exercises warm AND epoch-refresh paths."""
    rng = np.random.default_rng(seed)
    n = 200
    eng = DeltaEngine(n_nodes=n, refresh_every=4)
    for step, (ins, dels, edges) in enumerate(
            random_stream(rng, n, n_batches=10, max_batch=60)):
        eng.apply_updates(insert=ins, delete=dels)
        assert eng.n_edges == len(edges)
        q = eng.query()
        rho, mask, passes = pbahmani_np(materialize(edges, n))
        assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9), (
            f"step {step} refreshed={q.refreshed}")
        assert q.passes == passes
        assert np.array_equal(q.mask, mask)
        assert q.warm_density >= q.density - 1e-9


def test_engine_maintained_degrees_exact():
    """Incrementally-maintained degrees == recomputed degrees (the property
    that makes the warm peel bit-identical to a cold start)."""
    rng = np.random.default_rng(3)
    n = 150
    eng = DeltaEngine(n_nodes=n, refresh_every=10**9)
    for ins, dels, edges in random_stream(rng, n, n_batches=8, max_batch=50):
        eng.apply_updates(insert=ins, delete=dels)
        g = materialize(edges, n)
        expect = np.zeros(eng.node_capacity, np.int32)
        expect[:n] = g.degrees()
        assert np.array_equal(np.asarray(eng._deg), expect)


def test_engine_empty_and_deletion_to_empty():
    eng = DeltaEngine(n_nodes=20)
    assert eng.query().density == 0.0
    eng.apply_updates(insert=np.array([[0, 1], [1, 2], [0, 2]]))
    assert eng.query().density == pytest.approx(1.0)
    eng.apply_updates(delete=np.array([[0, 1], [1, 2], [0, 2]]))
    q = eng.query()
    assert q.density == 0.0 and q.mask.sum() == 0


def test_engine_cbds_matches_np():
    rng = np.random.default_rng(5)
    n = 120
    eng = DeltaEngine(n_nodes=n)
    edges = None
    for ins, dels, edges in random_stream(rng, n, n_batches=5, max_batch=80):
        eng.apply_updates(insert=ins, delete=dels)
    res = eng.cbds()
    ref = cbds_np(materialize(edges, n))
    assert res["density"] == pytest.approx(ref["density"], rel=1e-5)


def test_engine_zero_recompiles_after_warmup():
    """Acceptance criterion: repeated same-capacity update batches hit the
    jit caches — DeltaEngine.compile_count() must not move."""
    rng = np.random.default_rng(7)
    eng = DeltaEngine(n_nodes=500, capacity=4096, refresh_every=10**9)
    # warmup: compile the batch shape + the warm peel once
    eng.apply_updates(insert=rng.integers(0, 500, (48, 2)))
    eng.query()
    before = DeltaEngine.compile_count()
    for _ in range(12):
        ins = rng.integers(0, 500, (30, 2))
        dels = np.asarray(sorted(eng.buffer._slot))[:10]
        eng.apply_updates(insert=ins, delete=dels)
        eng.query()
    assert DeltaEngine.compile_count() == before, "hot path recompiled"


def test_engine_query_memoized_until_update():
    eng = DeltaEngine(n_nodes=30)
    eng.apply_updates(insert=np.array([[0, 1], [1, 2], [0, 2]]))
    q1 = eng.query()
    assert eng.query() is q1          # unchanged graph: cached result
    assert eng.metrics.n_queries == 1  # cache hits do no work
    eng.apply_updates(insert=np.array([[2, 3]]))
    q2 = eng.query()
    assert q2 is not q1               # updates invalidate the cache


def test_staleness_weighted_by_deleted_fraction():
    """ROADMAP follow-up: delete-dominated streams age the epoch faster
    (tombstone holes are what the compaction cleans up), while insert-only
    streams keep the historical one-per-batch cadence exactly."""
    # insert-only: refresh lands on the refresh_every-th batch, as before
    eng = DeltaEngine(n_nodes=50, refresh_every=4)
    for i in range(3):
        eng.apply_updates(insert=np.array([[i, i + 1]]))
        assert not eng.stale
    eng.apply_updates(insert=np.array([[10, 11]]))
    assert eng.stale
    q = eng.query()
    assert q.refreshed and not eng.stale

    # delete-dominated: an all-delete batch weighs 1 + DELETE_STALENESS_WEIGHT
    from repro.stream.delta import DELETE_STALENESS_WEIGHT

    eng2 = DeltaEngine(n_nodes=50, refresh_every=4)
    eng2.apply_updates(insert=np.array([[i, i + 1] for i in range(8)]))
    assert not eng2.stale
    eng2.apply_updates(delete=np.array([[0, 1], [1, 2]]))
    assert eng2._staleness == pytest.approx(2.0 + DELETE_STALENESS_WEIGHT)
    assert eng2.stale  # 2 batches instead of 4
    assert eng2.query().refreshed

    # no-op deletes (absent edges) are dropped: weight stays the insert-only 1
    eng3 = DeltaEngine(n_nodes=50, refresh_every=4)
    eng3.apply_updates(insert=np.array([[0, 1]]))
    eng3.apply_updates(delete=np.array([[30, 31]]))
    assert eng3._staleness == pytest.approx(2.0)
    # mixed batch: weight interpolates by the deleted-edge fraction
    eng3.apply_updates(insert=np.array([[2, 3], [3, 4], [4, 5]]),
                      delete=np.array([[0, 1]]))
    assert eng3._staleness == pytest.approx(
        3.0 + DELETE_STALENESS_WEIGHT * 0.25)


def test_engine_grow_shrink_grow_roundtrip():
    """ISSUE 3 acceptance: a grow -> shrink -> grow cycle returns correct
    results at every step, and revisited capacities are jit-cache hits —
    zero recompiles once every steady-state shape has been seen."""
    rng = np.random.default_rng(19)
    n = 256
    eng = DeltaEngine(n_nodes=n, capacity=256, refresh_every=10**9,
                      pruned=False)
    edges: set = set()

    def feed(k):
        """Insert k fresh edges in batches of <=48 (one padded batch shape)."""
        added = 0
        while added < k:
            ins = rng.integers(0, n, (48, 2))
            for u, v in ins:
                u, v = int(u), int(v)
                if u != v:
                    edges.add((min(u, v), max(u, v)))
            eng.apply_updates(insert=ins)
            added += 48

    def drop_to(k):
        pool = np.asarray(sorted(edges))
        dels = pool[k:]
        for u, v in dels:
            edges.discard((int(u), int(v)))
        for i in range(0, len(dels), 48):
            eng.apply_updates(delete=dels[i: i + 48])

    def check():
        q = eng.query()
        rho, mask, passes = pbahmani_np(materialize(edges, n))
        assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
        assert np.array_equal(q.mask, mask) and q.passes == passes

    # grow phase: visit capacities 256 -> 512 -> 1024, warming the query
    # AND refresh executables at each
    for target in (200, 400, 800):
        feed(target - len(edges))
        check()
        eng.refresh()
        check()
    assert eng.buffer.capacity == 1024
    caps_seen = DeltaEngine.compile_count()

    # shrink: contract to 120 live edges; the refresh compacts + halves
    drop_to(120)
    check()                      # pre-shrink query at peak capacity
    q = eng.refresh()            # epoch refresh triggers the shrink
    assert eng.buffer.capacity == 256, eng.buffer.capacity
    assert eng.metrics.n_buffer_shrinks == 1
    rho, mask, passes = pbahmani_np(materialize(edges, n))
    assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
    assert np.array_equal(q.mask, mask) and q.passes == passes
    check()

    # regrow through the same capacities: every shape is a cache hit
    feed(700 - len(edges))
    check()
    eng.refresh()
    check()
    assert eng.buffer.capacity == 1024
    assert DeltaEngine.compile_count() == caps_seen, (
        "revisited capacities recompiled")


def test_engine_delete_heavy_capacity_bound():
    """ISSUE 3 acceptance: a delete-heavy stream shrinking a tenant from
    2^16 to 2^10 live edges must end with buffer capacity <= 4x live size,
    with query results unchanged."""
    rng = np.random.default_rng(23)
    n = 4096
    pairs = rng.integers(0, n, (90_000, 2)).astype(np.int64)
    u = np.minimum(pairs[:, 0], pairs[:, 1])
    v = np.maximum(pairs[:, 0], pairs[:, 1])
    keep = u != v
    pairs = np.unique(np.stack([u[keep], v[keep]], axis=1), axis=0)
    assert pairs.shape[0] >= 2**16
    pairs = pairs[: 2**16]

    eng = DeltaEngine(n_nodes=n, refresh_every=10**9)
    eng.apply_updates(insert=pairs)
    assert eng.n_edges == 2**16
    assert eng.buffer.capacity == 2**16

    # delete down to 2^10 live edges (chunked: one padded batch shape)
    dels = pairs[2**10:]
    for i in range(0, len(dels), 8192):
        eng.apply_updates(delete=dels[i: i + 8192])
    assert eng.n_edges == 2**10
    q_before = eng.query()

    q_after = eng.refresh()      # epoch refresh compacts + shrinks
    live = eng.n_edges
    assert eng.buffer.capacity <= 4 * live, (eng.buffer.capacity, live)
    assert eng.metrics.n_buffer_shrinks >= 1
    # query results unchanged by the shrink
    assert q_after.density == q_before.density
    assert np.array_equal(q_after.mask, q_before.mask)
    assert q_after.passes == q_before.passes
    rho, mask, passes = pbahmani_np(eng.buffer.to_graph())
    assert q_after.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
    assert np.array_equal(q_after.mask, mask[:n]) and q_after.passes == passes


def test_engine_tombstone_autocompact_resyncs():
    """A delete-only stream that crosses the tombstone threshold forces a
    mid-stream compaction; the engine detects the generation bump, resyncs
    device state whole, and queries stay exact."""
    rng = np.random.default_rng(29)
    n = 128
    eng = DeltaEngine(n_nodes=n, capacity=256, refresh_every=10**9)
    # ~235 distinct edges: stays within the 256-slot capacity, so the 0.5
    # threshold is 128 holes — crossed by the delete chunks below
    ins = rng.integers(0, n, (240, 2))
    eng.apply_updates(insert=ins)
    edges = set(eng.buffer._slot)
    n0 = len(edges)
    assert eng.buffer.capacity == 256
    pool = np.asarray(sorted(edges))
    dels = pool[: n0 - 40]
    saw_compact = False
    for i in range(0, len(dels), 50):
        chunk = dels[i: i + 50]
        st_ = eng.apply_updates(delete=chunk)
        for u, v in chunk:
            edges.discard((int(u), int(v)))
        saw_compact = saw_compact or st_.regrew
        q = eng.query()
        rho, mask, passes = pbahmani_np(materialize(edges, n))
        assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
        assert np.array_equal(q.mask, mask) and q.passes == passes
    assert saw_compact, "tombstone threshold never fired"
    assert eng.buffer.tombstone_fraction <= 0.5


def test_engine_epoch_refresh_resyncs():
    rng = np.random.default_rng(11)
    n = 100
    eng = DeltaEngine(n_nodes=n, refresh_every=3)
    edges = None
    for i, (ins, dels, edges) in enumerate(
            random_stream(rng, n, n_batches=7, max_batch=40)):
        eng.apply_updates(insert=ins, delete=dels)
    assert eng.stale
    q = eng.query()
    assert q.refreshed
    assert not eng.stale
    rho, _, _ = pbahmani_np(materialize(edges, n))
    assert q.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
    assert eng.metrics.n_refreshes == 1


# ---------------------------------------------------------------------------
# GraphRegistry
# ---------------------------------------------------------------------------
def test_registry_register_get_lru_eviction():
    reg = GraphRegistry(max_tenants=2)
    reg.register("a", n_nodes=100)
    reg.register("b", n_nodes=200)
    reg.get("a")                      # touch: b becomes LRU
    reg.register("c", n_nodes=300)    # evicts b
    assert "a" in reg and "c" in reg and "b" not in reg
    assert reg.evictions == 1
    with pytest.raises(KeyError):
        reg.get("b")


def test_registry_reregister_conflict():
    reg = GraphRegistry()
    reg.register("t", n_nodes=100)
    assert reg.register("t", n_nodes=100) is reg.get("t")  # idempotent
    with pytest.raises(ValueError, match="already registered"):
        reg.register("t", n_nodes=5000)
    svc = StreamService()
    svc.create_tenant("t", n_nodes=100)
    r = svc.create_tenant("t", n_nodes=5000)
    assert not r.ok and "already registered" in r.error


def test_registry_bucketing_shares_executables():
    """Tenants bucketed to the same (node, edge, batch) capacities add zero
    compiled executables — the point of pow-2 normalization."""
    rng = np.random.default_rng(13)
    reg = GraphRegistry(max_tenants=8)
    a = reg.register("a", n_nodes=500, capacity=2048)
    a.apply_updates(insert=rng.integers(0, 500, (40, 2)))
    a.query()
    before = DeltaEngine.compile_count()
    for name, n in (("b", 400), ("c", 300), ("d", 257)):
        e = reg.register(name, n_nodes=n, capacity=2048)  # all bucket to 512
        assert e.node_capacity == 512
        e.apply_updates(insert=rng.integers(0, n, (40, 2)))
        e.query()
    assert DeltaEngine.compile_count() == before


def test_registry_stats():
    reg = GraphRegistry()
    eng = reg.register("t", n_nodes=100)
    eng.apply_updates(insert=np.array([[0, 1], [1, 2]]))
    eng.query()
    st_ = reg.stats("t")
    assert st_.n_edges == 2 and st_.n_update_batches == 1
    assert st_.n_queries == 1 and st_.node_capacity == 128


# ---------------------------------------------------------------------------
# StreamService
# ---------------------------------------------------------------------------
def test_service_end_to_end():
    svc = StreamService(max_tenants=4)
    assert svc.create_tenant("us", n_nodes=100).ok
    assert svc.create_tenant("eu", n_nodes=100).ok
    # a triangle in us, a single edge in eu
    assert svc.apply_updates("us", insert=np.array([[0, 1], [1, 2], [0, 2]])).ok
    assert svc.apply_updates("eu", insert=np.array([[5, 6]])).ok
    d = svc.density("us")
    assert d.ok and d.value["density"] == pytest.approx(1.0)
    m = svc.membership("us")
    assert m.ok and m.value["n_members"] == 3
    top = svc.top_k_densest(k=1)
    assert top.ok and top.value[0]["tenant"] == "us"
    s = svc.stats()
    assert s.ok and len(s.value) == 2
    assert svc.metrics.n_requests >= 7 and svc.metrics.n_errors == 0


def test_service_structured_errors():
    svc = StreamService()
    r = svc.density("nope")
    assert not r.ok and "nope" in r.error and r.latency_ms >= 0
    svc.create_tenant("t", n_nodes=10)
    r2 = svc.apply_updates("t", insert=np.array([[0, 99]]))
    assert not r2.ok and "out of range" in r2.error
    assert svc.metrics.n_errors == 2


# ---------------------------------------------------------------------------
# edge-stream IO
# ---------------------------------------------------------------------------
def test_edge_stream_roundtrip(tmp_path):
    rng = np.random.default_rng(17)
    n = 60
    events, edges = [], set()
    for _ in range(300):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in edges and rng.random() < 0.4:
            events.append(("-", u, v))
            edges.discard(key)
        else:
            events.append(("+", u, v))
            edges.add(key)
    path = str(tmp_path / "stream.txt")
    save_edge_stream(events, path)

    eng = DeltaEngine(n_nodes=n)
    for ins, dels in load_edge_stream(path, batch_size=64):
        eng.apply_updates(insert=ins, delete=dels)
    assert eng.n_edges == len(edges)
    rho, _, _ = pbahmani_np(materialize(edges, n))
    assert eng.query().density == pytest.approx(rho, rel=1e-6, abs=1e-9)


def test_edge_stream_intra_batch_net(tmp_path):
    path = str(tmp_path / "s.txt")
    save_edge_stream([("+", 0, 1), ("-", 0, 1), ("-", 2, 3), ("+", 2, 3)],
                     path)
    batches = list(load_edge_stream(path, batch_size=100))
    assert len(batches) == 1
    ins, dels = batches[0]
    assert [tuple(e) for e in ins.tolist()] == [(2, 3)]   # last op wins
    assert [tuple(e) for e in dels.tolist()] == [(0, 1)]


def test_edge_stream_bare_rows_are_inserts(tmp_path):
    path = tmp_path / "snap.txt"
    path.write_text("# comment\n0 1\n1 2\n+ 2 3\n")
    (ins, dels), = load_edge_stream(str(path))
    assert ins.shape[0] == 3 and dels.shape[0] == 0
