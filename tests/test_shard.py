"""Sharded streaming engine (ISSUE 3): the sharded==single-device parity
oracle, zero-recompile stability of the sharded executables, and the
registry/service opt-in wiring.

The load-bearing claim: because every cross-shard reduction in the sharded
engine (update histograms, peel degree deltas, scalar density state) is an
exact int32 psum, ``DeltaEngine(sharded=True)`` returns the *bit-identical*
(density, mask, passes) triple of the single-device engine — on a 1-device
mesh (asserted in-process below) and on forced multi-device CPU meshes
(asserted in subprocesses, density additionally fp32-checked against the
numpy oracle, per the acceptance criteria).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.pbahmani import pbahmani_np
from repro.graphs.graph import Graph
from repro.stream import DeltaEngine, GraphRegistry, StreamService
from repro.utils.compat import make_mesh_auto

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidev(script: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def materialize(edges: set, n_nodes: int) -> Graph:
    pairs = (np.asarray(sorted(edges), dtype=np.int64) if edges
             else np.zeros((0, 2), np.int64))
    return Graph.from_edges(pairs, n_nodes=n_nodes)


def stream_steps(rng, n_nodes, n_batches, max_batch):
    edges: set = set()
    for step in range(n_batches):
        ins = rng.integers(0, n_nodes, (int(rng.integers(1, max_batch)), 2))
        dels = None
        if edges and step % 2:
            pool = np.asarray(sorted(edges))
            dels = pool[rng.random(len(pool)) < 0.3]
            for u, v in dels:
                edges.discard((int(u), int(v)))
        for u, v in ins:
            u, v = int(u), int(v)
            if u != v:
                edges.add((min(u, v), max(u, v)))
        yield ins, dels, edges


# ---------------------------------------------------------------------------
# 1-device mesh, in-process: bit-identity is exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pruned", [True, False])
def test_sharded_bit_identical_on_one_device_mesh(pruned):
    """Acceptance criterion: on a 1-device mesh, DeltaEngine(sharded=True)
    returns bit-identical (density, mask, passes) to the single-device
    engine — across warm, pruned AND epoch-refresh query paths."""
    rng = np.random.default_rng(42)
    n = 200
    mesh = make_mesh_auto((1,), ("shard",))
    sh = DeltaEngine(n_nodes=n, refresh_every=4, pruned=pruned,
                     sharded=True, mesh=mesh)
    single = DeltaEngine(n_nodes=n, refresh_every=4, pruned=pruned)
    assert sh.n_shards == 1
    for step, (ins, dels, edges) in enumerate(
            stream_steps(rng, n, n_batches=8, max_batch=50)):
        sh.apply_updates(insert=ins, delete=dels)
        single.apply_updates(insert=ins, delete=dels)
        qs, qu = sh.query(), single.query()
        assert qs.density == qu.density, (step, qs.density, qu.density)
        assert np.array_equal(qs.mask, qu.mask), step
        assert qs.passes == qu.passes, step
        assert qs.refreshed == qu.refreshed, step
        rho, _, passes = pbahmani_np(materialize(edges, n))
        assert qs.density == pytest.approx(rho, rel=1e-6, abs=1e-9)
        assert qs.passes == passes


def test_sharded_zero_recompiles_after_warmup():
    """The pow-2 shape contract extends to the sharded executables: after
    one warm update+query cycle, repeated same-capacity batches must not
    move DeltaEngine.compile_count() (which includes SHARDED_JITS)."""
    rng = np.random.default_rng(7)
    eng = DeltaEngine(n_nodes=500, capacity=4096, refresh_every=10**9,
                      sharded=True)
    eng.apply_updates(insert=rng.integers(0, 500, (48, 2)))
    eng.query()
    before = DeltaEngine.compile_count()
    for _ in range(10):
        ins = rng.integers(0, 500, (30, 2))
        dels = np.asarray(sorted(eng.buffer._slot))[:10]
        eng.apply_updates(insert=ins, delete=dels)
        eng.query()
    assert DeltaEngine.compile_count() == before, "sharded hot path recompiled"


def test_sharded_engine_validation():
    with pytest.raises(ValueError, match="power-of-two"):
        DeltaEngine(n_nodes=50, sharded=True,
                    mesh=_FakeMesh())  # non-pow-2 device count


class _FakeMesh:
    """Minimal stand-in exposing a 3-device shape (mesh construction with a
    fabricated device count needs a subprocess; validation does not)."""
    shape = {"shard": 3}
    axis_names = ("shard",)


def test_sharded_cbds_matches_np():
    """CBDS on a sharded tenant == oracle. The peel inside cbds() runs
    through the shard_map tier (ISSUE 9 bugfix: it used to re-upload the
    state to a single device), so this doubles as a routing check."""
    from repro.core.cbds import cbds_np

    rng = np.random.default_rng(11)
    n = 100
    eng = DeltaEngine(n_nodes=n, sharded=True)
    edges = None
    for ins, dels, edges in stream_steps(rng, n, n_batches=4, max_batch=60):
        eng.apply_updates(insert=ins, delete=dels)
    res = eng.cbds()
    ref = cbds_np(materialize(edges, n))
    assert res["density"] == pytest.approx(ref["density"], rel=1e-5)


def test_registry_and_service_sharded_opt_in():
    reg = GraphRegistry(max_tenants=4)
    a = reg.register("plain", n_nodes=64)
    b = reg.register("sharded", n_nodes=64, sharded=True)
    assert not a.sharded and a.n_shards == 1
    assert b.sharded and b.n_shards >= 1
    st = reg.stats("sharded")
    assert st.sharded and st.n_shards == b.n_shards
    # re-registering with a conflicting sharded flag raises, like n_nodes/eps
    assert reg.register("sharded", n_nodes=64, sharded=True) is b
    with pytest.raises(ValueError, match="sharded"):
        reg.register("plain", n_nodes=64, sharded=True)

    svc = StreamService(max_tenants=4)
    r = svc.create_tenant("t", n_nodes=64, sharded=True)
    assert r.ok and r.value["n_shards"] >= 1
    svc.apply_updates("t", insert=np.array([[0, 1], [1, 2], [0, 2]]))
    d = svc.density("t")
    assert d.ok and d.value["density"] == pytest.approx(1.0)
    st = svc.stats("t")
    assert st.ok and st.value.sharded


# ---------------------------------------------------------------------------
# forced multi-device CPU meshes (subprocess, like tests/test_distributed.py)
# ---------------------------------------------------------------------------
MULTIDEV_SCRIPT = """
import numpy as np, jax
from repro.stream.delta import DeltaEngine
from repro.core.pbahmani import pbahmani_np
from repro.graphs.graph import Graph
from repro.utils.compat import make_mesh_auto

n_dev = len(jax.devices())
assert n_dev == %d, n_dev
mesh = make_mesh_auto((n_dev,), ("shard",))
rng = np.random.default_rng(3)
n = 300
engines = {
    "sharded_pruned": DeltaEngine(n_nodes=n, refresh_every=4,
                                  sharded=True, mesh=mesh),
    "sharded_plain": DeltaEngine(n_nodes=n, refresh_every=4, pruned=False,
                                 sharded=True, mesh=mesh),
    "single": DeltaEngine(n_nodes=n, refresh_every=4),
}
edges = set()
for step in range(8):
    ins = rng.integers(0, n, (60, 2))
    dels = None
    if edges and step %% 2:
        pool = np.asarray(sorted(edges))
        dels = pool[rng.random(len(pool)) < 0.3]
        for u, v in dels:
            edges.discard((int(u), int(v)))
    for u, v in ins:
        u, v = int(u), int(v)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    qs = {}
    for name, e in engines.items():
        e.apply_updates(insert=ins, delete=dels)
        qs[name] = e.query()
    pairs = (np.asarray(sorted(edges), dtype=np.int64) if edges
             else np.zeros((0, 2), np.int64))
    rho, mask, passes = pbahmani_np(Graph.from_edges(pairs, n_nodes=n))
    ref = qs["single"]
    # density must match the oracle to fp32 tolerance (acceptance), and the
    # sharded triples are in fact bit-identical to the single-device engine
    assert abs(ref.density - rho) <= 1e-6 * max(rho, 1.0)
    for name, q in qs.items():
        assert q.density == ref.density, (step, name, q.density, ref.density)
        assert np.array_equal(q.mask, ref.mask), (step, name)
        assert q.passes == ref.passes == passes, (step, name)

# steady state compiles nothing new on the multi-device mesh either:
# fixed batch shapes at fixed capacity, one warm cycle, then flat
eng = DeltaEngine(n_nodes=n, capacity=4096, refresh_every=10**9,
                  sharded=True, mesh=mesh)
eng.apply_updates(insert=rng.integers(0, n, (48, 2)))
eng.query()
before = DeltaEngine.compile_count()
for _ in range(6):
    ins = rng.integers(0, n, (30, 2))
    dels = np.asarray(sorted(eng.buffer._slot))[:10]
    eng.apply_updates(insert=ins, delete=dels)
    eng.query()
assert DeltaEngine.compile_count() == before, "multi-device path recompiled"
print("OK")
"""


@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_parity_multidevice(devices):
    """Acceptance criterion: on forced 2- and 4-device CPU meshes the
    sharded engine's densities match the cold recompute to fp32 tolerance
    (they are in fact bit-identical to the single-device engine)."""
    out = run_multidev(MULTIDEV_SCRIPT % devices, devices=devices)
    assert "OK" in out


# ---------------------------------------------------------------------------
# fused + sharded (ISSUE 9): vmap-inside-shard_map tenant bucket stacks
# ---------------------------------------------------------------------------
FUSED_MULTIDEV_SCRIPT = """
import numpy as np, jax
from repro.stream.registry import GraphRegistry
from repro.stream.delta import DeltaEngine
from repro.stream.fused import FusedEngine, ingest_group, query_group
from repro.obs.audit import AUDITOR

n_dev = len(jax.devices())
assert n_dev == %d, n_dev
N = 96
reg = GraphRegistry(fused=True, sharded=True)
names = ["a", "b", "c", "d"]
solo = {t: DeltaEngine(n_nodes=N) for t in names}
for t in names:
    eng = reg.register(t, n_nodes=N)
    assert isinstance(eng, FusedEngine) and eng.sharded, t
    assert eng.kind == "fused+sharded" and eng.n_shards == n_dev


def step_ups(step, roster):
    ups = {}
    for i, t in enumerate(roster):
        r = np.random.default_rng(100 + 7 * step + i)
        e = r.integers(0, N, size=(40, 2))
        e = e[e[:, 0] != e[:, 1]]
        dele = None
        if step >= 3:  # from step 3 on, delete ALL of the previous insert
            prev = np.random.default_rng(
                100 + 7 * (step - 1) + i).integers(0, N, size=(40, 2))
            dele = prev[prev[:, 0] != prev[:, 1]]
        ups[t] = (e, dele)
    return ups


# bit-identity: every tenant of the sharded bucket stack vs its own solo
# single-device engine, across ingest churn including deletes
for step in range(8):
    ups = step_ups(step, names)
    ingest_group(ups, reg.engines())
    for t in names:
        solo[t].apply_updates(insert=ups[t][0], delete=ups[t][1])
    res = query_group(reg.engines())
    for t in names:
        qs = solo[t].query()
        assert res[t].density == qs.density, (step, t)
        assert res[t].passes == qs.passes, (step, t)
        assert np.array_equal(np.asarray(res[t].mask),
                              np.asarray(qs.mask)), (step, t)

# cbds and fixed-round refinement route through the same sharded batched
# tier and stay bit-identical to the solo engines
for t in ["a", "b", "c"]:
    cf, cs = reg.get(t).cbds(), solo[t].cbds()
    assert cf["density"] == cs["density"], t
    assert cf["n_legit"] == cs["n_legit"], t
rf = query_group({t: reg.get(t) for t in ["a", "b", "c"]},
                 refine=True, target_gap=-1.0, max_refine_rounds=4)
for t in ["a", "b", "c"]:
    rs = solo[t].query(refine=True, target_gap=-1.0, max_refine_rounds=4)
    assert rf[t].density == rs.density, t
    assert rf[t].certificate.rel_gap == rs.certificate.rel_gap, t

# steady state on the live mesh: stationary churn must not trip the
# recompile auditor (a NEW plan-bucket shape may compile once — a
# first-call event, not a steady-state recompile)
for step in range(8, 14):
    ups = step_ups(step, names)
    ingest_group(ups, reg.engines())
    query_group(reg.engines())
AUDITOR.sync()
assert AUDITOR.n_steady_recompiles == 0, AUDITOR.snapshot(last=20)

# join/evict churn: swapping a same-shape tenant into the warm bucket is a
# lane-row swap, not a compile event — ingest+query over the full roster
# (the warmed 4-lane group shape) stays strictly flat
reg.remove("d")
reg.register("e", n_nodes=N)
c0 = DeltaEngine.compile_count()
ups = step_ups(1, ["a", "b", "c", "e"])
ingest_group(ups, reg.engines())
query_group(reg.engines())
c1 = DeltaEngine.compile_count()
assert c1 == c0, (c0, c1)
print("OK fused+sharded")
"""


@pytest.mark.parametrize("devices", [2, 4])
def test_fused_sharded_parity_multidevice(devices):
    """ISSUE 9 acceptance: fused+sharded tenants (vmap-inside-shard_map
    bucket stacks) return per-tenant results bit-identical to the solo
    single-device engine on forced multi-device meshes, with zero audited
    steady-state recompiles and compile-free join/evict on the live mesh."""
    out = run_multidev(FUSED_MULTIDEV_SCRIPT % devices, devices=devices)
    assert "OK fused+sharded" in out
