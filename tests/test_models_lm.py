"""Transformer family: decode==prefill, flash==plain, MoE paths agree,
training reduces loss. All at smoke scale on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _attend, flash_attention
from repro.models.moe import MoEConfig, init_moe_params, moe_dense, moe_ep
from repro.models.moe_tp import moe_tp
from repro.models.transformer import (
    TransformerConfig, decode_step, forward, init_cache, init_params, loss_fn,
)
from repro.optim import adamw


@pytest.fixture(scope="module")
def gqa_cfg():
    return TransformerConfig(name="t", n_layers=3, d_model=32, n_heads=4,
                             n_kv_heads=2, d_ff=64, vocab=101, qkv_bias=True,
                             rope_theta=1e4)


@pytest.fixture(scope="module")
def mla_moe_cfg():
    return TransformerConfig(
        name="t2", n_layers=4, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab=101, attn="mla", q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=8,
        qk_rope_dim=4, v_head_dim=8, n_dense_layers=2, mtp=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_model=32, d_ff=48, n_shared=1,
                      capacity_factor=4.0))


def _toks(shape, vocab=101, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), shape, 0, vocab)


@pytest.mark.parametrize("cfg_name", ["gqa_cfg", "mla_moe_cfg"])
def test_decode_matches_prefill(cfg_name, request):
    cfg = request.getfixturevalue(cfg_name)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = _toks((2, 16))
    logits, _ = forward(p, toks, cfg)
    cache = init_cache(cfg, 2, 16)
    outs = []
    for t in range(12):
        lg, cache = decode_step(p, cache, toks[:, t], jnp.asarray(t), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits[:, :12]),
                               rtol=6e-3, atol=6e-3)


@pytest.mark.parametrize("cfg_name", ["gqa_cfg", "mla_moe_cfg"])
def test_prefill_cache_continues(cfg_name, request):
    cfg = request.getfixturevalue(cfg_name)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = _toks((2, 16))
    logits, _aux, cache = forward(p, toks, cfg, return_cache=True)
    padspec = ((0, 0), (0, 0), (0, 4)) + ((0, 0),) * (
        jax.tree.leaves(cache)[0].ndim - 3)
    cache = jax.tree.map(lambda x: jnp.pad(x, padspec[:x.ndim]), cache)
    nxt = jnp.full((2,), 5)
    lg, _ = decode_step(p, cache, nxt, jnp.asarray(16), cfg)
    ref_toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    ref, _ = forward(p, ref_toks, cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                               rtol=6e-3, atol=6e-3)


def test_sliding_window_decode(gqa_cfg):
    """Ring-buffer window cache == full cache when seq <= window."""
    from dataclasses import replace
    cfg_w = replace(gqa_cfg, sliding_window=32)
    p = init_params(jax.random.PRNGKey(0), cfg_w)
    toks = _toks((2, 20))
    cache_full = init_cache(gqa_cfg, 2, 20)
    cache_win = init_cache(cfg_w, 2, 64)   # window 32 => ring of 32
    assert jax.tree.leaves(cache_win)[0].shape[2] == 32
    for t in range(20):
        lg_f, cache_full = decode_step(p, cache_full, toks[:, t],
                                       jnp.asarray(t), gqa_cfg)
        lg_w, cache_win = decode_step(p, cache_win, toks[:, t],
                                      jnp.asarray(t), cfg_w)
    np.testing.assert_allclose(np.asarray(lg_w), np.asarray(lg_f),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("qc,kc", [(32, 32), (128, 32), (64, 128)])
def test_flash_matches_plain(qc, kc):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 24))
    k = jax.random.normal(ks[1], (2, 128, 2, 24))
    v = jax.random.normal(ks[2], (2, 128, 2, 16))
    o1 = flash_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
    o2 = _attend(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-4, atol=3e-4)


def test_moe_paths_agree():
    cfg = MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32, n_shared=1,
                    capacity_factor=8.0)
    p = jax.tree.map(lambda a: a[0],
                     init_moe_params(jax.random.PRNGKey(0), cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    yd, _ = moe_dense(x, p, cfg)
    ye, _ = moe_ep(x, p, cfg)
    yt, _ = moe_tp(x, p, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ye), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yt), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop; output stays finite and close
    in norm (the framework trade documented in models/moe.py)."""
    cfg_tight = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                          capacity_factor=1.0)
    p = jax.tree.map(lambda a: a[0],
                     init_moe_params(jax.random.PRNGKey(0), cfg_tight, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 16))
    y, aux = moe_ep(x, p, cfg_tight)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.abs(y).sum()) > 0


@pytest.mark.parametrize("cfg_name", ["gqa_cfg", "mla_moe_cfg"])
def test_train_reduces_loss(cfg_name, request):
    cfg = request.getfixturevalue(cfg_name)
    p = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-2, weight_decay=0.0)
    st = opt.init(p)
    toks = _toks((4, 16), seed=7)

    @jax.jit
    def step(p, st):
        loss, g = jax.value_and_grad(lambda q: loss_fn(q, toks, toks, cfg))(p)
        p2, st2 = opt.update(g, st, p)
        return p2, st2, loss

    losses = []
    for _ in range(12):
        p, st, loss = step(p, st)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses
    assert np.isfinite(losses).all()


def test_param_count_sane():
    """n_params/n_active_params used by the roofline: sanity at smoke scale."""
    cfg = TransformerConfig(name="c", n_layers=2, d_model=16, n_heads=2,
                            n_kv_heads=2, d_ff=32, vocab=64,
                            moe=MoEConfig(n_experts=4, top_k=2, d_model=16,
                                          d_ff=32), n_dense_layers=1)
    total = cfg.n_params()
    active = cfg.n_active_params()
    assert 0 < active < total
    # exactly: total - (E-k) * per_expert * n_moe_layers
    per_e = 3 * 16 * 32
    assert total - active == (4 - 2) * per_e * 1


def test_int8_kv_cache_decode(gqa_cfg):
    """int8 KV cache (EXPERIMENTS §Perf #3): <=3% rel error, identical
    greedy tokens vs the f32-cache decode."""
    from dataclasses import replace
    cfg8 = replace(gqa_cfg, kv_cache_dtype="int8")
    p = init_params(jax.random.PRNGKey(0), gqa_cfg)
    toks = _toks((2, 24))
    ref, _ = forward(p, toks, gqa_cfg)
    cache = init_cache(cfg8, 2, 24)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    outs = []
    for t in range(24):
        lg, cache = decode_step(p, cache, toks[:, t], jnp.asarray(t), cfg8)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.03, rel
    # near-ties may flip under quantization; random-init logits are ~flat,
    # so require high (not perfect) greedy agreement
    agree = float((jnp.argmax(dec, -1) == jnp.argmax(ref[:, :24], -1)).mean())
    assert agree >= 0.9, agree


def test_zero3_param_specs_cover_all_leaves():
    from repro.models.transformer import param_specs_zero3
    from repro.configs import get_arch
    from repro.utils.compat import make_mesh_auto
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    cfg = get_arch("qwen2.5-3b").smoke
    specs = param_specs_zero3(cfg, mesh)
    p = init_params(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(specs) == jax.tree.structure(p)
